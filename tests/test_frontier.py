"""Warm-start frontier tests: the zero-solve answer paths (exact hit,
infeasibility monotonicity, equal-makespan interpolation), the
one-refinement-solve fallback, the verify-before-serve gate, and the
``sweep()`` integration (O(1) solves on a revisited chain).

The property test asserts the frontier's core contract: whatever it
answers is *indistinguishable* from a direct solve — same feasibility,
same optimal makespan — it only ever saves work, never changes results.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.chain import Chain
from repro.plan import Budget, InfeasiblePlanError, PlanRequest, build_plan
from repro.plan.api import sweep
from repro.store import MemoryBackend, ObjectStore, WarmStartFrontier

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover — CI always installs the test extra
    HAVE_HYPOTHESIS = False

NUM_SLOTS = 64


def _chain(L: int = 10, seed: int = 0) -> Chain:
    rng = np.random.default_rng(seed)
    n = L + 1
    return Chain.make(
        uf=rng.integers(1, 5, n).astype(float),
        ub=rng.integers(1, 5, n).astype(float),
        wa=rng.integers(1, 4, n).astype(float),
        wabar=rng.integers(1, 6, n).astype(float),
    )


def _template() -> PlanRequest:
    return PlanRequest(strategy="optimal", num_slots=NUM_SLOTS)


def _solver(chain, template, counter):
    def solve(budget):
        counter[0] += 1
        try:
            return build_plan(
                dataclasses.replace(template, budget=Budget.bytes(budget)),
                chain,
            )
        except InfeasiblePlanError:
            return None

    return solve


def _frontier() -> WarmStartFrontier:
    return WarmStartFrontier(ObjectStore(MemoryBackend()))


def test_exact_hit_zero_solves():
    ch, tmpl, fr = _chain(), _template(), _frontier()
    budget = ch.store_all_peak() * 0.6
    solves = [0]
    first = fr.query(ch, tmpl, budget, solve=_solver(ch, tmpl, solves))
    assert first.source == "solved" and solves[0] == 1
    again = fr.query(ch, tmpl, budget, solve=_solver(ch, tmpl, solves))
    assert again.source == "exact" and again.solves == 0 and solves[0] == 1
    assert again.plan.expected_time == first.plan.expected_time


def test_infeasibility_is_monotone():
    ch, tmpl, fr = _chain(), _template(), _frontier()
    # find an infeasible budget by recording a tiny one
    solves = [0]
    tiny = fr.query(ch, tmpl, 1.0, solve=_solver(ch, tmpl, solves))
    assert not tiny.feasible and solves[0] == 1
    # anything at or below a recorded infeasible budget: zero solves
    below = fr.query(ch, tmpl, 0.5, solve=_solver(ch, tmpl, solves))
    assert not below.feasible
    assert below.solves == 0 and solves[0] == 1
    assert below.source == "infeasible"


def test_equal_time_bracket_interpolates():
    ch, tmpl, fr = _chain(), _template(), _frontier()
    peak = ch.store_all_peak()
    solves = [0]
    solve = _solver(ch, tmpl, solves)
    # both budgets clear the store-all peak *plus* the worst-case slot
    # rounding slack (one slot per stage), so both plans are recompute-free
    # with the identical optimal makespan
    lo = fr.query(ch, tmpl, peak * 1.5, solve=solve)
    hi = fr.query(ch, tmpl, peak * 2.5, solve=solve)
    assert lo.feasible and hi.feasible and solves[0] == 2
    assert lo.plan.expected_time == hi.plan.expected_time
    mid = fr.query(ch, tmpl, peak * 2.0, solve=solve)
    assert mid.source == "interpolated" and mid.solves == 0
    assert solves[0] == 2, "bracketed query must not re-solve"
    assert mid.plan.expected_time == lo.plan.expected_time
    assert mid.plan.verify().ok


def test_undecided_query_costs_exactly_one_solve():
    ch, tmpl, fr = _chain(), _template(), _frontier()
    peak = ch.store_all_peak()
    solves = [0]
    solve = _solver(ch, tmpl, solves)
    fr.query(ch, tmpl, peak * 0.4, solve=solve)
    fr.query(ch, tmpl, peak * 0.9, solve=solve)
    assert solves[0] == 2
    # 0.6x sits between two points with different makespans: the bracket
    # does not pinch, so this costs exactly one more solve — never two
    answer = fr.query(ch, tmpl, peak * 0.6, solve=solve)
    assert answer.solves == 1 and solves[0] == 3
    # ... and the refinement was recorded: asking again is free
    again = fr.query(ch, tmpl, peak * 0.6, solve=solve)
    assert again.solves == 0 and solves[0] == 3


def test_served_plans_are_verified_and_tamper_is_quarantined():
    ch, tmpl, fr = _chain(), _template(), _frontier()
    budget = ch.store_all_peak() * 0.7
    solves = [0]
    fr.query(ch, tmpl, budget, solve=_solver(ch, tmpl, solves))
    # doctor the *stored* plan: forged makespan = metadata drift on verify
    points = fr.points(ch, tmpl)
    points[0]["plan"].expected_time += 1.0
    fr._save(fr._key(ch, tmpl), points)
    answer = fr.query(ch, tmpl, budget, solve=_solver(ch, tmpl, solves))
    # the tampered plan never crosses the boundary — the query fell back to
    # a fresh solve and the entry was quarantined
    assert answer.source == "solved" and solves[0] == 2
    assert answer.plan.verify().ok
    assert fr.points(ch, tmpl) == [] or all(
        p["plan"] is None or p["plan"].verify().ok
        for p in fr.points(ch, tmpl)
    )


def test_sweep_routes_through_frontier_o1_solves():
    ch, tmpl, fr = _chain(), _template(), _frontier()
    fracs = [0.4, 0.6, 0.8, 1.0]
    first = sweep(ch, fracs, tmpl, frontier=fr)
    solves = [0]
    # the same sweep again: every point answered from the stored frontier
    again = sweep(ch, fracs, tmpl, frontier=fr)
    assert solves[0] == 0
    for a, b in zip(first, again):
        assert a.feasible == b.feasible
        if a.feasible:
            assert a.plan.expected_time == b.plan.expected_time
    # an off-grid budget above the store-all peak interpolates for free
    wide = sweep(ch, [1.5, 2.5], tmpl, frontier=fr)
    assert all(p.feasible for p in wide)
    mid = fr.query(ch, tmpl, ch.store_all_peak() * 2.0)
    assert mid.source == "interpolated" and mid.solves == 0


def test_sweep_without_frontier_matches_with(tmp_path):
    ch, tmpl = _chain(), _template()
    fracs = [0.5, 0.75, 1.0]
    direct = sweep(ch, fracs, tmpl, use_frontier=False)
    warm = sweep(ch, fracs, tmpl, frontier=_frontier())
    for d, w in zip(direct, warm):
        assert d.feasible == w.feasible
        if d.feasible:
            assert d.plan.expected_time == w.plan.expected_time


@pytest.mark.slow
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_frontier_answers_match_direct_solve_property():
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 50),
        length=st.integers(2, 8),
        seed_fracs=st.lists(
            st.floats(0.2, 1.6), min_size=1, max_size=4, unique=True
        ),
        query_frac=st.floats(0.2, 1.6),
    )
    def prop(seed, length, seed_fracs, query_frac):
        ch = _chain(length, seed=seed)
        tmpl = PlanRequest(strategy="optimal", num_slots=24)
        fr = _frontier()
        peak = ch.store_all_peak()
        solves = [0]
        solve = _solver(ch, tmpl, solves)
        for frac in seed_fracs:
            fr.query(ch, tmpl, peak * frac, solve=solve)
        seeded = solves[0]
        answer = fr.query(ch, tmpl, peak * query_frac, solve=solve)
        # at most one refinement solve, whatever the frontier held
        assert solves[0] - seeded <= 1
        # never infeasible-when-feasible, never a worse (or better) time
        # than the direct solve: the frontier only saves work
        try:
            direct = build_plan(
                dataclasses.replace(
                    tmpl, budget=Budget.bytes(peak * query_frac)
                ),
                ch,
            )
        except InfeasiblePlanError:
            direct = None
        if direct is None:
            assert not answer.feasible
        else:
            assert answer.feasible
            rel = abs(answer.plan.expected_time - direct.expected_time)
            assert rel <= 1e-9 * max(direct.expected_time, 1.0)
            assert answer.plan.verify().ok

    prop()
