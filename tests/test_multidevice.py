"""Multi-device integration (subprocess-isolated so the main test process
keeps its single CPU device): sharded train step on a (2,2,2) pod mesh,
shard_map MoE vs local MoE equivalence, elastic checkpoint restore 8→4
devices, and compressed DP all-reduce on a real mesh."""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_pod_mesh():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.distributed.sharding import axis_rules
        from repro.configs.shapes import ShapeSpec, input_specs
        from repro.launch.steps import build_cell
        from repro.launch.mesh import make_production_mesh
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = smoke_config("qwen1.5-4b", n_heads=4, n_kv_heads=4, vocab_size=256)
        shape = ShapeSpec("t", "train", 32, 8)
        with axis_rules(mesh):
            jitted, args, rules, extra = build_cell(cfg, shape, mesh,
                                                    policy="rotor:auto")
            with axis_rules(mesh, rules):
                # materialize real values for the specs and execute
                import numpy as np
                def conc(sds):
                    arr = (np.random.default_rng(0)
                           .integers(0, 200, sds.shape).astype(np.int32)
                           if jnp.issubdtype(sds.dtype, jnp.integer)
                           else np.random.default_rng(1)
                           .standard_normal(sds.shape).astype(sds.dtype))
                    return jax.device_put(arr, sds.sharding)
                params, opt, batch, step = jax.tree.map(conc, args)
                p2, o2, metrics = jitted(params, opt, batch, step)
                assert np.isfinite(float(metrics["loss"]))
                print("LOSS", float(metrics["loss"]))
    """)
    assert "LOSS" in out


def test_moe_shard_map_matches_local():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.distributed.sharding import axis_rules
        from repro.models import mlp as mlp_mod
        cfg = smoke_config("deepseek-v2-lite-16b", moe_capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = mlp_mod.moe_init(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, cfg.d_model))
        y_local, aux_local = mlp_mod.moe_apply(p, cfg, x)  # no mesh: local path
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with axis_rules(mesh):
            y_ep, aux_ep = jax.jit(lambda p, x: mlp_mod.moe_apply(p, cfg, x))(p, x)
        np.testing.assert_allclose(np.asarray(y_local, np.float64),
                                   np.asarray(y_ep, np.float64),
                                   rtol=2e-4, atol=2e-5)
        print("MOE_MATCH")
    """)
    assert "MOE_MATCH" in out


def test_elastic_restore_8_to_4():
    code_save = """
        import jax, jax.numpy as jnp
        from repro.ckpt.manager import CheckpointManager
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((8,), ("data",))
        w = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                           NamedSharding(mesh, P("data", None)))
        CheckpointManager("/tmp/elastic_ck", keep=1).save(3, {"w": w})
        print("SAVED")
    """
    code_load = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.ckpt.manager import CheckpointManager
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((4,), ("data",))
        target = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        shards = {"w": NamedSharding(mesh, P("data", None))}
        step, st = CheckpointManager("/tmp/elastic_ck").restore(
            target, shardings=shards)
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(st["w"]), np.arange(64).reshape(8, 8))
        assert len(st["w"].sharding.device_set) == 4
        print("RESTORED")
    """
    assert "SAVED" in run_py(code_save, devices=8)
    assert "RESTORED" in run_py(code_load, devices=4)


def test_compressed_allreduce_on_mesh():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum_mean, ef_init
        mesh = jax.make_mesh((4,), ("data",))
        # per-member gradients: leading axis = member
        g_all = jnp.arange(4 * 8, dtype=jnp.float32).reshape(4, 8) / 7.0

        def per_member(g_stacked, e_stacked):
            g = {"w": g_stacked[0]}
            e = {"w": e_stacked[0]}
            mean, e2 = compressed_psum_mean(g, e, axes=("data",), n_members=4)
            return mean["w"][None], e2["w"][None]

        from repro.compat import shard_map_unchecked
        fn = jax.jit(shard_map_unchecked(per_member, mesh=mesh,
                                         in_specs=(P("data"), P("data")),
                                         out_specs=(P("data"), P("data"))))
        mean, e2 = fn(g_all, jnp.zeros((4, 8)))
        true_mean = np.asarray(g_all).mean(axis=0)
        got = np.asarray(mean)[0]
        scale = np.abs(np.asarray(g_all)).max() / 127.0
        assert np.max(np.abs(got - true_mean)) <= scale + 1e-6
        # every member agrees on the reduced value
        for i in range(4):
            np.testing.assert_allclose(np.asarray(mean)[i], got, rtol=1e-6)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_dryrun_entrypoint_smoke():
    """The real dryrun module on a reduced device count (8) — proves the
    entrypoint works end-to-end without the 512-device cost in CI."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = """
import repro.launch.dryrun as dr
import jax
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
import repro.launch.mesh as m
m.make_production_mesh = lambda multi_pod=False: mesh
rec = dr.run_cell("qwen1.5-4b", "train_4k", False, "rotor:auto",
                  "/tmp/dryrun_test", overrides={
                      "num_layers": 4, "layer_kinds": ("dense",)*4,
                      "d_model": 64, "n_heads": 4, "n_kv_heads": 4,
                      "head_dim": 16, "d_ff": 128, "vocab_size": 256,
                      "n_chunks": 2})
assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
print("DRYRUN_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560, env=env, cwd=REPO)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "DRYRUN_OK" in out.stdout
