"""Paper §4.1: with heterogeneous activation sizes, memory persistency is no
longer optimal — some chains admit a *non-persistent* schedule strictly
faster than every persistent one.

The paper's Figure-2 instance depends on ā sizes only shown graphically, so
we validate the *claim* itself: exhaustive search over the exact Table-1
operation model (including value drops) exhibits a strict gap on concrete
heterogeneous instances, while the DP still matches the best persistent
schedule.  The pinned instance below was found by search and verified by the
Dijkstra oracle (L=2, M=9: persistent optimum 13, non-persistent 10).
"""

import numpy as np

from repro.core.bruteforce import optimal_time
from repro.core.chain import Chain
from repro.core.schedule import simulate
from repro.core.solver import solve_optimal

# L = 2 real stages + loss stage; found by random search, minimal-ish.
PINNED = Chain.make(
    uf=[1.0, 4.0, 4.0],
    ub=[0.0, 0.0, 0.0],
    wa=[2.0, 3.0, 3.0],
    wabar=[2.0, 4.0, 2.0],
    wdelta=[0.0, 1.0, 1.0],
)
M = 9.0


def test_nonpersistent_strictly_beats_persistent():
    t_pers, sched_p = optimal_time(PINNED, M, persistent_only=True,
                                   return_schedule=True)
    t_any, sched_np = optimal_time(PINNED, M, persistent_only=False,
                                   return_schedule=True)
    assert np.isfinite(t_pers) and np.isfinite(t_any)
    assert t_any < t_pers - 1e-9, (t_any, t_pers)
    assert t_pers == 13.0 and t_any == 10.0
    # both witness schedules are valid under the limit
    assert simulate(PINNED, sched_p, M + 1e-9).valid
    assert simulate(PINNED, sched_np, M + 1e-9).valid
    # the non-persistent witness really is non-persistent
    res = simulate(PINNED, sched_np, M + 1e-9,
                   track_checkpoint_persistence=True)
    assert not res.valid and res.error == "non-persistent"


def test_dp_equals_best_persistent_on_counterexample():
    sol = solve_optimal(PINNED, M, num_slots=int(M))
    assert sol.feasible
    assert abs(sol.expected_time - 13.0) < 1e-9


def test_homogeneous_gap_observation():
    """Beyond-paper observation (EXPERIMENTS.md §Findings): the paper's §4.1
    exchange argument ("homogeneous sizes ⇒ persistency is optimal") is
    stated for chains of plain activation checkpoints; in the *generalized*
    Table-1 model, where ``B^l`` may read ``a^{l-1}`` non-destructively out
    of a live ``ā^{l-1}``, non-persistent schedules can win even with fully
    homogeneous sizes (drop a bare ``a`` mid-stream, serve its backward from
    a later ``ā``).  We pin one such instance so the behaviour is tracked."""
    rng = np.random.default_rng(0)
    found_gap = False
    for _ in range(8):
        n = int(rng.integers(2, 4)) + 1
        ch = Chain.make(
            uf=rng.integers(1, 5, n).astype(float),
            ub=np.zeros(n),
            wa=np.ones(n),
            wabar=np.ones(n),
            wdelta=np.ones(n),
        )
        peak = simulate(ch, __import__(
            "repro.core.schedule", fromlist=["Schedule"]
        ).Schedule.store_all(ch.length)).peak_mem
        for m in range(2, int(peak) + 1):
            p = optimal_time(ch, float(m), persistent_only=True)
            a = optimal_time(ch, float(m), persistent_only=False)
            if np.isfinite(p):
                assert a <= p + 1e-9  # non-persistent space is a superset
                if a < p - 1e-9:
                    found_gap = True
    assert found_gap
