"""The `repro.plan` planning API and its policy-string back-compat shim.

Covers the redesign's acceptance criteria: every documented policy string
resolves through `repro.plan` to a bit-identical schedule and expected_time
as the pre-redesign resolution (inlined here as the reference), `MemoryPlan`
round-trips through disk and refuses a mismatched chain, budget parsing
rejects the garbage the old regex accepted, the offload-plan-as-tree error
has exactly one resolution path, and `num_slots`/`impl` thread uniformly
from every entry point."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core.chain import Chain, HostTransferModel
from repro.core.policies import (make_policy_plan, make_policy_tree,
                                 parse_budget, policy_to_request)
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_min_memory, solve_optimal, tree_to_schedule
from repro.plan import (Budget, DEFAULT_NUM_SLOTS, InfeasiblePlanError,
                        MemoryPlan, PlanRequest, StalePlanError, build_plan,
                        min_memory_plan, parse_size, register_solver, solver_for,
                        sweep, two_tier_fallback)

from helpers import make_mlp_chain, random_chain, tree_allclose


# ---------------------------------------------------------------------------
# budget / size parsing (satellite: harden _parse_size / parse_budget)
# ---------------------------------------------------------------------------

def test_parse_size_documented_forms():
    assert parse_size("1.5G") == 1.5e9
    assert parse_size("800M") == 8e8
    assert parse_size("2e9") == 2e9
    assert parse_size("1.5e9") == 1.5e9
    assert parse_size("123") == 123.0
    assert parse_size("0") == 0.0
    assert parse_size(".5K") == 500.0
    assert parse_size(" 4G ") == 4e9  # stray whitespace tolerated


@pytest.mark.parametrize("garbage", ["1e", "--5G", "", "G", "1..5", "x",
                                     "e9", "+5G", "-5G", "1.5GG", "nan",
                                     "inf", "0x10", "1,5G"])
def test_parse_size_rejects_garbage(garbage):
    """The old ``[\\d.eE+-]+`` regex accepted these and blew up in float()
    with a confusing message; now they fail fast with a clear one."""
    with pytest.raises(ValueError, match="expected a number|cannot parse"):
        parse_size(garbage)


def test_parse_budget_forms_and_errors():
    ch = Chain.homogeneous(4)
    peak = simulate(ch, Schedule.store_all(4)).peak_mem
    assert parse_budget("1.5G", None) == 1.5e9
    assert parse_budget("x0.5", ch) == 0.5 * peak
    assert parse_budget("0", None) == 0.0
    with pytest.raises(ValueError, match="profiled chain"):
        parse_budget("x0.5", None)
    with pytest.raises(ValueError, match="'x' followed by a number"):
        parse_budget("x", ch)
    with pytest.raises(ValueError, match="'x' followed by a number"):
        parse_budget("x--5", ch)
    with pytest.raises(ValueError, match="auto"):
        parse_budget("auto", ch)  # resolvable only through the launch path


def test_budget_dataclass():
    assert Budget.parse("x0.25") == Budget.fraction(0.25)
    assert Budget.parse("8G") == Budget.bytes(8e9)
    assert Budget.parse("auto") == Budget.auto()
    assert Budget.bytes(10).resolve() == 10.0
    assert Budget.fraction(0.5).resolve(store_all_peak=100.0) == 50.0
    assert Budget.auto().resolve(auto_budget=7.0) == 7.0
    assert Budget.auto().resolve(auto_budget=lambda: 9.0) == 9.0
    with pytest.raises(ValueError):
        Budget("parsecs", 1.0)
    with pytest.raises(ValueError):
        Budget.bytes(-1).resolve()


# ---------------------------------------------------------------------------
# back-compat: documented policy strings == pre-redesign resolution, bitwise
# ---------------------------------------------------------------------------

def _legacy_resolve(policy, chain, num_slots=500):
    """The pre-redesign ``core/policies.py`` resolution, inlined verbatim as
    the reference: returns ``(ops, expected_time | None, uses_offload)``."""
    from repro.core.rematerialize import (full_remat_tree, periodic_tree,
                                          sequential_tree)
    L = chain.length
    if policy == "none":
        return tree_to_schedule(sequential_tree(L), L).ops, None, False
    if policy == "full":
        return tree_to_schedule(full_remat_tree(L), L).ops, None, False
    if policy.startswith("periodic:"):
        t = periodic_tree(L, int(policy.split(":", 1)[1]))
        return tree_to_schedule(t, L).ops, None, False
    if policy.startswith(("rotor:", "revolve:")):
        kind, spec = policy.split(":", 1)
        if spec.startswith("x"):
            peak = simulate(chain, Schedule.store_all(L)).peak_mem
            budget = float(spec[1:]) * peak
        else:
            budget = parse_size(spec)
        sol = solve_optimal(chain, budget, num_slots=num_slots,
                            allow_fall=(kind == "rotor"))
        assert sol.feasible
        return tree_to_schedule(sol.tree, L).ops, sol.expected_time, False
    assert policy.startswith("optimal_offload")
    from repro.offload.solver import solve_optimal_offload, tree_uses_offload
    parts = policy.split(":")
    if parts[1].startswith("x"):
        peak = simulate(chain, Schedule.store_all(L)).peak_mem
        budget = float(parts[1][1:]) * peak
    else:
        budget = parse_size(parts[1])
    host = chain.host
    if len(parts) >= 3:
        bw = parse_size(parts[2])
        host = HostTransferModel(bandwidth_d2h=bw) if bw > 0 else None
    elif host is None:
        host = HostTransferModel.pcie_gen3()
    if host is None or not host.enabled:
        sol = solve_optimal(chain, budget, num_slots=num_slots)
        assert sol.feasible
        return sol.schedule.ops, sol.expected_time, False
    sol = solve_optimal_offload(chain.with_host(host), budget,
                                num_slots=num_slots)
    assert sol.feasible
    return sol.schedule.ops, sol.expected_time, tree_uses_offload(sol.tree)


def _compat_chain(seed):
    rng = np.random.default_rng(seed)
    ch = random_chain(rng, max_len=6)
    return ch.with_host(HostTransferModel(bandwidth_d2h=50.0, latency=0.1))


@pytest.mark.parametrize("policy", [
    "none", "full", "periodic:2", "periodic:3",
    "rotor:x0.8", "rotor:x1.0", "revolve:x1.0",
    "optimal_offload:x0.8", "optimal_offload:x0.8:100", "optimal_offload:x1.0:0",
])
@pytest.mark.parametrize("seed", [0, 3])
def test_policy_strings_bit_identical_to_legacy(policy, seed):
    """Acceptance criterion: every documented policy form resolves through
    `repro.plan` to exactly the schedule and makespan of the pre-redesign
    string path."""
    chain = _compat_chain(seed)
    ref_ops, ref_time, ref_off = _legacy_resolve(policy, chain)
    plan = make_policy_plan(policy, chain)
    assert plan.schedule.ops == ref_ops
    assert plan.uses_offload == ref_off
    if ref_time is not None:
        assert plan.solution.expected_time == ref_time  # bitwise
    # the underlying MemoryPlan agrees with itself
    mp = plan.plan
    assert mp.policy == policy
    assert mp.schedule.ops == ref_ops
    if not ref_off:
        # tree path produces the same ops through the same resolution
        tree = make_policy_tree(policy, chain)
        assert tree_to_schedule(tree, chain.length).ops == ref_ops


def test_rotor_infeasible_still_memoryerror():
    ch = _compat_chain(1)
    with pytest.raises(MemoryError):
        make_policy_tree("rotor:1", ch)  # 1 byte: infeasible
    with pytest.raises(InfeasiblePlanError):
        make_policy_plan("rotor:1", ch)  # the new exception IS a MemoryError


def test_unknown_policy_and_bad_segments():
    with pytest.raises(ValueError, match="unknown remat policy"):
        make_policy_tree("magic:1", None, length=4)
    with pytest.raises(ValueError, match="integer segment"):
        policy_to_request("periodic:x")


# ---------------------------------------------------------------------------
# offload-plan-as-tree: one resolution path, one error (satellite)
# ---------------------------------------------------------------------------

def _offload_bearing_chain():
    """A chain + budget whose three-tier optimum genuinely uses the host."""
    for seed in range(20):
        rng = np.random.default_rng(300 + seed)
        ch = random_chain(rng, max_len=5).with_host(
            HostTransferModel(bandwidth_d2h=1000.0))
        f2 = solve_min_memory(ch, num_slots=200)
        f3 = min_memory_plan(ch, tiers=("device", "host"), num_slots=200)
        if f3.budget_bytes < f2.mem_limit - 1e-9:
            return ch, 0.5 * (f3.budget_bytes + f2.mem_limit)
    raise AssertionError("no offload-bearing test chain found")


def test_offload_plan_requested_as_tree_raises():
    ch, budget = _offload_bearing_chain()
    policy = f"optimal_offload:{budget:.6e}"
    plan = make_policy_plan(policy, ch, num_slots=200)
    assert plan.uses_offload
    with pytest.raises(ValueError, match="nested remat cannot express"):
        make_policy_tree(policy, ch, num_slots=200)


def test_two_tier_fallback_degrades_offload_plan():
    ch, budget = _offload_bearing_chain()
    plan = build_plan(PlanRequest(strategy="optimal", budget=Budget.bytes(budget),
                                  tiers=("device", "host"), num_slots=200), ch)
    assert plan.uses_offload
    fb = two_tier_fallback(plan, ch)
    assert not fb.uses_offload and fb.remat_expressible
    # budget between the floors is two-tier-infeasible -> min-memory fallback
    assert fb.solution.feasible


# ---------------------------------------------------------------------------
# MemoryPlan: introspection, round-trip, stale-chain rejection
# ---------------------------------------------------------------------------

def test_plan_save_load_roundtrip(tmp_path):
    rng = np.random.default_rng(7)
    ch = random_chain(rng, max_len=6)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    plan = build_plan(PlanRequest(strategy="optimal",
                                  budget=Budget.bytes(peak), num_slots=100), ch)
    p = str(tmp_path / "plan.pkl")
    plan.save(p)
    loaded = MemoryPlan.load(p, chain=ch)
    assert loaded.schedule.ops == plan.schedule.ops
    assert loaded.expected_time == plan.expected_time
    assert loaded.chain_hash == plan.chain_hash
    assert loaded.request == plan.request
    # loading without a chain skips validation
    assert MemoryPlan.load(p).schedule.ops == plan.schedule.ops


def test_plan_load_rejects_stale_chain(tmp_path):
    rng = np.random.default_rng(8)
    ch = random_chain(rng, max_len=6)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    plan = build_plan(PlanRequest(strategy="optimal",
                                  budget=Budget.bytes(peak), num_slots=100), ch)
    p = str(tmp_path / "plan.pkl")
    plan.save(p)
    # any content change invalidates: a stage got slower
    uf2 = ch.uf.copy(); uf2[0] += 1.0
    changed = dataclasses.replace(ch, uf=uf2)
    with pytest.raises(StalePlanError, match="re-plan"):
        MemoryPlan.load(p, chain=changed)
    # ...or the host link changed
    hosted = ch.with_host(HostTransferModel(bandwidth_d2h=1.0))
    with pytest.raises(StalePlanError):
        MemoryPlan.load(p, chain=hosted)
    with pytest.raises(ValueError, match="not a saved MemoryPlan"):
        bad = str(tmp_path / "bad.pkl")
        import pickle
        with open(bad, "wb") as f:
            pickle.dump({"not": "a plan"}, f)
        MemoryPlan.load(bad)


def test_plan_summary_and_timeline():
    rng = np.random.default_rng(9)
    ch = random_chain(rng, max_len=6)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    plan = build_plan(PlanRequest(strategy="optimal",
                                  budget=Budget.bytes(0.8 * peak),
                                  num_slots=200), ch)
    s = plan.summary()
    assert "MemoryPlan" in s and "predicted" in s and "executor" in s
    tl = plan.timeline()
    assert len(tl) == len(plan.schedule.ops)
    assert tl[0]["t_start"] == 0.0
    assert abs(tl[-1]["t_end"] - plan.expected_time) < 1e-12
    assert all(r["t_end"] >= r["t_start"] for r in tl)
    stats = plan.stats()
    assert stats["executor"] == "jit-nested-remat"
    import json
    json.dumps(stats)  # JSON-serializable for dry-run artifacts


def test_structural_plans_without_chain():
    plan = build_plan(PlanRequest(strategy="periodic", segments=3), length=6)
    assert plan.chain is None and plan.chain_hash is None
    assert math.isnan(plan.expected_time)
    assert plan.remat_expressible
    with pytest.raises(ValueError, match="timeline"):
        plan.timeline()
    with pytest.raises(ValueError, match="need chain or length"):
        build_plan(PlanRequest(strategy="store_all"))
    with pytest.raises(ValueError, match="needs a profiled chain"):
        build_plan(PlanRequest(strategy="optimal", budget=Budget.bytes(1e9)))
    with pytest.raises(ValueError, match="needs a budget"):
        build_plan(PlanRequest(strategy="optimal"), Chain.homogeneous(3))


# ---------------------------------------------------------------------------
# sweep: the time-vs-budget frontier
# ---------------------------------------------------------------------------

def test_sweep_frontier_monotone():
    rng = np.random.default_rng(11)
    ch = random_chain(rng, max_len=6)
    # 1.1: ceil-discretization can make the exact store-all peak infeasible
    # (§5.2's 1+1/S overestimation) — grant the usual slack at the top point
    fracs = (0.3, 0.5, 0.7, 0.85, 1.1)
    pts = sweep(ch, fracs, PlanRequest(strategy="optimal", num_slots=200))
    assert [p.fraction for p in pts] == list(fracs)
    assert pts[-1].feasible  # with slack, store-all always admits a schedule
    times = [p.plan.expected_time for p in pts if p.feasible]
    assert all(a >= b - 1e-12 for a, b in zip(times, times[1:])), \
        "more memory can never make the optimum slower"
    # infeasible points are reported, not raised
    floor = min_memory_plan(ch, num_slots=200)
    tiny = sweep(ch, (0.001,), PlanRequest(strategy="optimal", num_slots=200))
    if floor.budget_bytes > 0.001 * simulate(
            ch, Schedule.store_all(ch.length)).peak_mem:
        assert not tiny[0].feasible


def test_sweep_offload_dominates_two_tier():
    ch = _compat_chain(5)
    fracs = (0.5, 0.75, 1.0)
    two = sweep(ch, fracs, PlanRequest(strategy="optimal", num_slots=200))
    three = sweep(ch, fracs, PlanRequest(strategy="optimal",
                                         tiers=("device", "host"),
                                         num_slots=200))
    for p2, p3 in zip(two, three):
        if p2.feasible:
            assert p3.feasible
            assert (p3.plan.expected_time
                    <= p2.plan.expected_time + 1e-9)


# ---------------------------------------------------------------------------
# num_slots / impl threading (satellite)
# ---------------------------------------------------------------------------

def test_num_slots_and_impl_thread_through_request():
    rng = np.random.default_rng(12)
    ch = random_chain(rng, max_len=5)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    plan = build_plan(PlanRequest(strategy="optimal",
                                  budget=Budget.bytes(peak),
                                  num_slots=123, impl="reference"), ch)
    assert plan.solution.num_slots == 123
    assert plan.request.resolved_num_slots == 123
    # default resolves to the single shared constant
    assert PlanRequest(strategy="optimal").resolved_num_slots \
        == DEFAULT_NUM_SLOTS
    # the shim threads it too (the old surface hard-coded 500)
    pp = make_policy_plan("rotor:x1.0", ch, num_slots=77)
    assert pp.solution.num_slots == 77
    # banded and reference kernels agree through the API
    ref = build_plan(PlanRequest(strategy="optimal", budget=Budget.bytes(peak),
                                 num_slots=123, impl="banded"), ch)
    assert ref.schedule.ops == plan.schedule.ops


def test_num_slots_threads_through_launch_planner():
    """launch/steps + TrainLoopConfig expose one knob that reaches the DP."""
    import jax
    from repro.configs import smoke_config
    from repro.configs.shapes import ShapeSpec, input_specs
    from repro.distributed.sharding import DEFAULT_RULES, axis_rules
    from repro.launch.steps import plan_training
    from repro.models.lm import StagedLM
    from repro.runtime.train_loop import TrainLoopConfig

    cfg = smoke_config("qwen1.5-4b", num_layers=4, layer_kinds=("dense",) * 4,
                       n_chunks=4)
    model = StagedLM(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("train", "train", 16, 2)
    with axis_rules(mesh, DEFAULT_RULES):
        batch_specs = input_specs(cfg, shape)
        plan, chain = plan_training(model, batch_specs, mesh, DEFAULT_RULES,
                                    "rotor:x0.9", num_slots=111)
    assert plan.solution.num_slots == 111
    # TrainLoopConfig carries the same knobs the loop hands to plan_training
    loop = TrainLoopConfig(num_slots=111, solver_impl="reference")
    assert loop.num_slots == 111 and loop.solver_impl == "reference"


# ---------------------------------------------------------------------------
# registry: the tier -> solver extension point
# ---------------------------------------------------------------------------

def test_registry_known_and_unknown_tiers():
    assert solver_for(("device",)).key == "device"
    assert solver_for(("device", "host")).key == "device+host"
    with pytest.raises(ValueError, match="no solver registered"):
        solver_for(("device", "nvme"))
    with pytest.raises(ValueError, match="already registered"):
        register_solver("device", lambda *a, **k: None,
                        lambda *a, **k: None)


def test_registry_custom_tier_plugs_in():
    """A new storage tier only needs a registry entry — build_plan picks it
    up with no other code changes."""
    calls = {}

    def fake_solve(chain, budget, *, num_slots, allow_fall, impl):
        calls["solve"] = (budget, num_slots, allow_fall, impl)
        return solve_optimal(chain, budget, num_slots=num_slots,
                             allow_fall=allow_fall, impl=impl)

    import repro.plan.registry as reg
    key = "device+nvme-test"
    try:
        register_solver(key, fake_solve, lambda *a, **k: None)
        entry = solver_for(("device", "nvme-test"))
        ch = Chain.homogeneous(4)
        peak = simulate(ch, Schedule.store_all(4)).peak_mem
        plan = build_plan(PlanRequest(strategy="optimal",
                                      budget=Budget.bytes(peak),
                                      tiers=("device", "nvme-test"),
                                      num_slots=50), ch)
        assert calls["solve"] == (peak, 50, True, None)
        assert plan.solution.feasible
    finally:
        reg._REGISTRY.pop(key, None)


# ---------------------------------------------------------------------------
# uniform executor binding
# ---------------------------------------------------------------------------

def test_bind_jit_remat_matches_reference():
    import jax
    from repro.core import profile_stages_measured, reference_grads

    stages, params, x = make_mlp_chain(5)
    chain = profile_stages_measured(stages, params, x, repeats=1)
    peak = simulate(chain, Schedule.store_all(5)).peak_mem
    plan = build_plan(PlanRequest(strategy="optimal",
                                  budget=Budget.bytes(0.6 * peak),
                                  num_slots=300), chain)
    bound = plan.bind(stages)
    assert bound.jittable
    out_ref, g_ref, dx_ref = reference_grads(stages, params, x)
    out, g, dx = bound.value_and_grad(params, x)
    tree_allclose(g, g_ref)
    tree_allclose(dx, dx_ref)
    # forward is a pure jit-able function on this path
    np.testing.assert_allclose(float(jax.jit(bound.forward)(params, x)),
                               float(out_ref), rtol=1e-6)
    # plan.execute always runs the faithful eager op sequence
    out2, g2, dx2 = plan.execute(stages, params, x)
    tree_allclose(g2, g_ref)


def test_bind_offload_eager_matches_reference():
    from repro.core import profile_stages_measured, reference_grads

    L = 6
    stages, params, x = make_mlp_chain(L)
    chain = profile_stages_measured(stages, params, x, repeats=1)
    bw = sum(chain.wa) / max(float(chain.uf.sum()), 1e-9)
    chain = chain.with_host(HostTransferModel(bandwidth_d2h=bw))
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    plan = build_plan(PlanRequest(strategy="optimal",
                                  budget=Budget.bytes(0.35 * peak),
                                  tiers=("device", "host"),
                                  num_slots=300), chain)
    assert plan.uses_offload and not plan.remat_expressible
    bound = plan.bind(stages)
    assert not bound.jittable
    out_ref, g_ref, dx_ref = reference_grads(stages, params, x)
    out, g, dx = bound.value_and_grad(params, x)
    tree_allclose(g, g_ref)
    tree_allclose(dx, dx_ref)
    np.testing.assert_allclose(float(bound.forward(params, x)),
                               float(out_ref), rtol=1e-6)


# ---------------------------------------------------------------------------
# MemoryPlan persistence: URI targets + component-named staleness
# ---------------------------------------------------------------------------


def _roundtrip_plan(seed=21):
    rng = np.random.default_rng(seed)
    ch = random_chain(rng, max_len=6)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    plan = build_plan(
        PlanRequest(
            strategy="optimal", budget=Budget.bytes(peak), num_slots=100
        ),
        ch,
    )
    return ch, plan


def test_plan_save_load_file_uri(tmp_path):
    ch, plan = _roundtrip_plan()
    uri = f"file://{tmp_path}/plan.bin"
    plan.save(uri)
    loaded = MemoryPlan.load(uri, chain=ch)
    assert loaded.schedule.ops == plan.schedule.ops
    assert loaded.expected_time == plan.expected_time


def test_plan_save_load_store_uri():
    from repro.store import config as store_config

    ch, plan = _roundtrip_plan()
    store_config.configure("memory://")
    try:
        uri = "store://plans/api-roundtrip"
        plan.save(uri)
        loaded = MemoryPlan.load(uri, chain=ch)
        assert loaded.schedule.ops == plan.schedule.ops
        with pytest.raises(FileNotFoundError):
            MemoryPlan.load("store://plans/never-written")
    finally:
        store_config.reset()


def test_stale_plan_error_names_diverged_component(tmp_path, monkeypatch):
    ch, plan = _roundtrip_plan()
    p = str(tmp_path / "plan.bin")
    plan.save(p)
    # chain divergence is named
    uf2 = ch.uf.copy()
    uf2[0] += 1.0
    with pytest.raises(StalePlanError, match="chain"):
        MemoryPlan.load(p, chain=dataclasses.replace(ch, uf=uf2))
    # request divergence is named
    other_req = dataclasses.replace(
        plan.request, budget=Budget.bytes(plan.budget_bytes * 0.5)
    )
    with pytest.raises(StalePlanError, match="request"):
        MemoryPlan.load(p, chain=ch, request=other_req)
    # code divergence (a solver edit since the save) is named
    from repro.core import solver_cache

    monkeypatch.setattr(solver_cache, "_code_fingerprint", "f" * 64)
    with pytest.raises(StalePlanError, match="code"):
        MemoryPlan.load(p, chain=ch)
